"""Roofline machinery: HLO collective parser + analytic workload sanity."""
import pytest

from repro.configs import get_config
from repro.launch.shapes import SHAPES
from repro.roofline.analysis import (
    analytic_workload,
    parse_collectives,
)

HLO = """
HloModule jit_step

%while_body_1 (arg: (s32[], bf16[])) -> (s32[], bf16[]) {
  %all-reduce.1 = f32[1024]{0} all-reduce(%x), replica_groups={}
  %all-gather.2 = bf16[512,64]{1,0} all-gather(%y), dimensions={0}
}

ENTRY %main () -> f32[] {
  %all-reduce.9 = f32[256]{0} all-reduce(%z), replica_groups={}
  %tuple-coll = (f32[128]{0}, f32[128]{0}) all-to-all(%a, %b), dimensions={0}
}
"""


def test_parser_counts_and_weights():
    out = parse_collectives(HLO, while_mult=10.0)
    assert out["n_ops"] == 4
    # while-body ops x10; all-reduce wire factor 2
    assert out["all-reduce"] == pytest.approx(1024 * 4 * 2 * 10 + 256 * 4 * 2)
    assert out["all-gather"] == pytest.approx(512 * 64 * 2 * 10)
    assert out["all-to-all"] == pytest.approx(2 * 128 * 4)


def test_analytic_train_flops_scale():
    """6ND sanity: granite-3-2b train_4k ~ 6 * 2.6e9 * 1.05e6 tokens."""
    cfg = get_config("granite-3-2b")
    wl = analytic_workload(cfg, SHAPES["train_4k"])
    N = cfg.param_count()
    T = 256 * 4096
    assert wl["model_flops"] == pytest.approx(6 * N_active(cfg) * T, rel=1e-6)
    assert wl["total_flops"] > wl["model_flops"] * 0.8  # attention adds, never subtracts
    assert wl["total_flops"] < wl["model_flops"] * 3.0


def N_active(cfg):
    return cfg.active_param_count()


def test_moe_active_vs_total():
    cfg = get_config("olmoe-1b-7b")
    assert cfg.param_count() > 5e9                      # ~7B total
    assert cfg.active_param_count() < 2.2e9             # ~1.3B active
    cfg2 = get_config("deepseek-moe-16b")
    assert cfg2.param_count() > 12e9
    assert cfg2.active_param_count() < 4.5e9


def test_decode_memory_dominated_by_cache():
    cfg = get_config("internlm2-20b")
    wl = analytic_workload(cfg, SHAPES["decode_32k"])
    assert wl["cache_bytes"] > 5 * wl["active_params"]  # cache streams dominate

def test_long500k_window_cuts_cache():
    cfg = get_config("granite-3-8b")
    wl_full = analytic_workload(cfg, SHAPES["decode_32k"])
    wl_long = analytic_workload(cfg, SHAPES["long_500k"])
    # 128-batch 32k full cache is far bigger than 1-batch windowed cache
    assert wl_long["cache_bytes"] < wl_full["cache_bytes"] / 100


def test_param_counts_plausible():
    expect = {
        "granite-3-8b": (7e9, 10e9),
        "granite-3-2b": (2e9, 3.6e9),
        "qwen3-8b": (7e9, 10e9),
        "internlm2-20b": (17e9, 23e9),
        "mamba2-130m": (0.1e9, 0.2e9),
        "paligemma-3b": (2e9, 3.5e9),
        "recurrentgemma-2b": (2e9, 3.4e9),
        "seamless-m4t-large-v2": (0.5e9, 1.3e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo < n < hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9},{hi/1e9}]"
