"""Serving-runtime hardening: latency_stats guards, drain/shutdown paths,
and the token-backlog virtual queue (policy + scheduler + serve threading).
"""
import copy
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.control import TokenBacklogAware
from repro.models import init_params
from repro.runtime import (
    Engine,
    EngineConfig,
    PagedEngine,
    PagedEngineConfig,
    PolicyScheduler,
    RequestSource,
    TokenAwareScheduler,
    latency_stats,
    serve,
)
from repro.runtime.request import Request

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("granite-3-2b", smoke=True)
    params = init_params(KEY, cfg)
    return cfg, params


def _mk_reqs(cfg, n, max_new=4, seed=3, prompt_len=16, min_prompt=2):
    src = RequestSource(vocab_size=cfg.vocab_size, prompt_len=prompt_len,
                        min_prompt_len=min_prompt, raw_rate=n,
                        max_new_tokens=max_new, seed=seed)
    return src.poll(0, float(n))


def _dense(cfg, params, **kw):
    return Engine(cfg, params, EngineConfig(batch_slots=4, prompt_len=16,
                                            cache_len=64, **kw))


# ----------------------------------------------------------- latency_stats
def _fake_engine(finished, active=(), pending=()):
    return types.SimpleNamespace(finished=list(finished), active=list(active),
                                 pending=list(pending))


def _req(rid, arrival=0, start=None, finish=None):
    r = Request(rid=rid, arrival_slot=arrival, tokens=np.zeros(4, np.int32))
    r.start_slot, r.finish_slot = start, finish
    return r


def test_latency_stats_empty_waits_nonempty_totals():
    """The PR-4 bug: waits and totals filter on different fields, so
    np.percentile(waits) could throw on [] while totals was non-empty —
    e.g. requests retired with start_slot reset by a preemption."""
    eng = _fake_engine([_req(0, start=None, finish=5),
                        _req(1, start=None, finish=7)])
    stats = latency_stats(eng)          # must not raise
    assert stats["n"] == 2
    assert stats["total_p50"] == 6.0
    assert "wait_p50" not in stats and "wait_p99" not in stats


def test_latency_stats_counts_admitted_but_unfinished():
    eng = _fake_engine(
        finished=[_req(0, start=1, finish=3)],
        active=[_req(1), None, _req(2)],
        pending=[_req(3)],
    )
    stats = latency_stats(eng)
    assert stats["n"] == 1
    assert stats["admitted_but_unfinished"] == 3
    assert stats["wait_p50"] == 1.0 and stats["total_p50"] == 3.0


def test_latency_stats_all_empty():
    stats = latency_stats(_fake_engine([]))
    assert stats == {"n": 0, "admitted_but_unfinished": 0}


# ------------------------------------------------------------ drain paths
@pytest.mark.parametrize("mode", ["sync", "chunked"])
def test_drain_zero_inflight_is_noop(setup, mode):
    cfg, params = setup
    eng = _dense(cfg, params)
    out = eng.drain()                   # nothing ever dispatched
    assert out["served"] == 0 and eng.finished == []
    step = eng.step_slot_sync if mode == "sync" else eng.step_slot_chunked
    step(0, n_steps=2)                  # empty slot: no pending, no active
    assert eng.drain()["served"] == 0 and eng.finished == []


@pytest.mark.parametrize("mode", ["sync", "chunked"])
def test_double_drain_is_noop_with_stable_totals(setup, mode):
    cfg, params = setup
    eng = _dense(cfg, params)
    reqs = _mk_reqs(cfg, 6)
    eng.submit(copy.deepcopy(reqs))
    step = eng.step_slot_sync if mode == "sync" else eng.step_slot_chunked
    for t in range(40):
        if len(eng.finished) == len(reqs):
            break
        step(t, n_steps=2)
    first = eng.drain()["served"]
    total = len(eng.finished)
    assert total == len(reqs)
    second = eng.drain()                # must be a no-op
    assert second["served"] == 0 and len(eng.finished) == total
    assert sum(eng.served_history) + first == total


def test_drain_after_preemption_paged(setup):
    """Preemption bounces requests back to pending; drain mid-flight must
    neither lose nor double-count them, and resuming serves every request
    with stable served totals."""
    cfg, params = setup
    eng = PagedEngine(cfg, params, PagedEngineConfig(
        prompt_len=16, cache_len=64, page_size=8, num_pages=8, max_active=6,
        chunk_size=8))
    reqs = _mk_reqs(cfg, 6, max_new=8, seed=11)
    eng.submit(copy.deepcopy(reqs))
    drained = 0
    for t in range(6):
        eng.step_slot_chunked(t, n_steps=2)
    drained += eng.drain()["served"]    # mid-flight shutdown flush
    assert eng.drain()["served"] == 0   # and it is idempotent
    for t in range(6, 200):
        if len(eng.finished) == len(reqs):
            break
        eng.step_slot_chunked(t, n_steps=2)
    drained += eng.drain()["served"]
    assert len(eng.finished) == len(reqs)
    assert sum(eng.served_history) + drained == len(reqs)
    assert eng.preemptions >= 0
    # every page returned: nothing leaks across preempt/retire/drain
    assert eng.allocator.used_pages == 0
    eng.allocator.check()


def test_chunked_admission_rejects_prompt_larger_than_pool(setup):
    """A prompt that cannot fit the whole page pool can never activate; it
    must be refused loudly at admission instead of livelocking the chunk
    scheduler in per-slot allocation failures."""
    cfg, params = setup
    eng = PagedEngine(cfg, params, PagedEngineConfig(
        prompt_len=64, cache_len=128, page_size=8, num_pages=6,
        max_active=4, chunk_size=8))
    big = Request(rid=0, arrival_slot=0,
                  tokens=np.arange(64, dtype=np.int32), max_new_tokens=2)
    eng.submit([big])
    with pytest.raises(ValueError, match="pool holds"):
        eng.step_slot_chunked(0, n_steps=2)
    assert eng.pending and eng.pending[0] is big  # raise before popping


# ----------------------------------------------------- token-backlog queue
def test_engine_token_backlog_tracks_pending_and_cursors(setup):
    cfg, params = setup
    eng = _dense(cfg, params, chunk_size=4, chunk_budget=4)
    reqs = [Request(rid=i, arrival_slot=0,
                    tokens=np.arange(12, dtype=np.int32), max_new_tokens=2)
            for i in range(6)]
    eng.submit(copy.deepcopy(reqs))
    assert eng.token_backlog() == 6 * 12
    eng.step_slot_chunked(0, n_steps=1)
    # 4 rows admitted; one 4-token chunk shipped (budget): backlog dropped
    # by exactly the tokens written, queued prompts still count in full
    assert eng.token_backlog() == 6 * 12 - 4
    eng.step_slot_chunked(1, n_steps=1)
    assert eng.token_backlog() == 6 * 12 - 8


def test_token_backlog_policy_virtual_queue_discipline():
    """Z advances as max(Z + tok - budget, 0) on observe; a loaded queue
    prices admission down (monotone: larger Z => chosen rate no higher)."""
    pol = TokenBacklogAware(rates=tuple(float(f) for f in range(1, 9)),
                            V=50.0, tokens_per_request=16.0, token_budget=32.0)
    carry = pol.init()
    carry = pol.observe(carry, 100.0)
    assert float(carry.value) == pytest.approx(68.0)
    carry = pol.observe(carry, 10.0)
    assert float(carry.value) == pytest.approx(46.0)
    f_loaded, _ = pol.act(carry, jnp.float32(5.0))
    f_empty, _ = pol.act(pol.init(), jnp.float32(5.0))
    assert float(f_loaded) <= float(f_empty)
    carry = pol.init()
    for _ in range(10):
        carry = pol.observe(carry, 0.0)
    assert float(carry.value) == 0.0    # never negative


def test_scheduler_token_aware_table_path_matches_policy_act():
    """The scheduler's shared jitted table dispatch must equal the policy's
    own act() for every observed (backlog, token_backlog) pair."""
    pol = TokenBacklogAware(rates=tuple(float(f) for f in range(1, 9)),
                            V=40.0, tokens_per_request=8.0, token_budget=16.0)
    sch = PolicyScheduler(policy=pol, capacity=64)
    carry = pol.init()
    for q, tok in [(0, 0.0), (3, 40.0), (12, 120.0), (2, 0.0), (30, 300.0)]:
        carry = pol.observe(carry, tok)
        want, _ = pol.act(carry, jnp.float32(q))
        got = sch.control(q, token_backlog=tok)
        assert got == float(want), (q, tok)


def test_serve_threads_token_backlog_observation(setup):
    """End to end: a chunked serve loop under TokenAwareScheduler must feed
    the engine's token backlog into the virtual queue (it advances past 0
    under a long-prompt flood) and still account for every request."""
    cfg, params = setup
    eng = Engine(cfg, params, EngineConfig(batch_slots=4, prompt_len=32,
                                           cache_len=64, chunk_size=4,
                                           chunk_budget=8))
    sch = TokenAwareScheduler(rates=tuple(float(f) for f in range(1, 7)),
                              V=20.0, tokens_per_request=32.0,
                              token_budget=8.0, capacity=64)
    src = RequestSource(vocab_size=cfg.vocab_size, prompt_len=32,
                        min_prompt_len=24, raw_rate=6, max_new_tokens=3,
                        seed=5)
    tr = serve(eng, sch, src, horizon=12, steps_per_slot=2, chunked=True)
    assert float(sch._carry.value) > 0.0     # the token queue saw pressure
    assert int(tr["dispatches"].max()) <= 1  # one dispatch per slot, still
    assert int(tr["syncs"].max()) == 0
    assert int(tr["served"].sum()) == len(eng.finished)
