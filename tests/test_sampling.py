"""Per-request sampling layer: unit semantics + disruption invariance.

Unit half (no model): ``SamplingParams`` validation rejects bad knobs at
construction (= admission), temperature 0.0 and 1e-9 route to exact greedy
argmax instead of an fp32-overflowing divide, the top-k cutoff keeps
exactly min(k, V) survivors with ties broken to the lowest token id,
top_k > vocab_size clamps to full-vocabulary sampling, penalties read the
generated history only, and a row's draw is invariant to where in the
batch it sits (the single-row oracle agrees at every placement).

Engine half (the headline ISSUE-9 regression): one seeded sampled request
must produce the identical token stream when served solo at row 0, packed
at a different row among greedy neighbors, preempted-and-recomputed on a
page-starved paged engine, and requeued across replicas by a fleet
failure — the request-keyed RNG (seed, rid, age) makes the stream a pure
function of the request, not of its placement history.
"""
import copy

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.control import FleetRouter
from repro.models import init_params
from repro.runtime import (
    Engine,
    EngineConfig,
    PagedEngine,
    PagedEngineConfig,
    ReplicaFleet,
    Request,
    SamplingParams,
)
from repro.runtime.sampling import row_tables, sample_oracle, sample_rows

KEY = jax.random.PRNGKey(0)
_CACHE = {}


def _setup():
    if "m" not in _CACHE:
        cfg = get_config("granite-3-2b", smoke=True)
        _CACHE["m"] = (cfg, init_params(KEY, cfg))
    return _CACHE["m"]


# ---------------------------------------------------------------- validation
@pytest.mark.parametrize("kw,msg", [
    (dict(temperature=-0.5), "temperature must be >= 0"),
    (dict(temperature=float("nan")), "temperature must be >= 0"),
    (dict(top_k=-1), "top_k must be >= 0"),
    (dict(top_p=0.0), "top_p must be in"),
    (dict(top_p=1.5), "top_p must be in"),
    (dict(repetition_penalty=0.0), "repetition_penalty must be > 0"),
])
def test_bad_params_rejected_at_construction(kw, msg):
    """Admission-time validation: a request can never carry invalid knobs
    to a device dispatch."""
    with pytest.raises(ValueError, match=msg):
        SamplingParams(**kw)


# ------------------------------------------------------------- greedy routing
@pytest.mark.parametrize("temp", [0.0, 1e-9])
def test_temperature_zero_is_exact_greedy(temp):
    """temperature <= 1e-6 must take the argmax branch — the old sampler's
    max(T, 1e-6) divide sent temperature=0 through logits * 1e6 (fp32
    overflow -> inf/nan draws). Large-magnitude logits make the overflow
    observable if the divide ever comes back."""
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(3, 97)) * 1e4, jnp.float32)
    p = SamplingParams(temperature=temp, seed=1)
    samp = row_tables([(p, r) for r in (5, 6, 7)], 0)
    out = sample_rows(logits, samp, jnp.zeros(3, jnp.int32))
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(jnp.argmax(logits, axis=-1)))


# ------------------------------------------------------------------- top-k
def _draw_support(logits_row, p, rid=9, n=300):
    """The set of tokens the sampler actually emits for one row across n
    ages (each age is an independent request-keyed draw)."""
    B = n
    samp = row_tables([(p, rid)] * B, 0)
    lg = jnp.broadcast_to(jnp.asarray(logits_row, jnp.float32), (B, len(logits_row)))
    out = sample_rows(lg, samp, jnp.arange(B, dtype=jnp.int32))
    return set(np.asarray(out).tolist())


def test_topk_tied_logits_keeps_exactly_k():
    """Tied logits at the cutoff: the old ``lg < kth`` mask kept every token
    tied with the k-th (k=2 on four tied maxima sampled from 4 tokens).
    The stable-sort cutoff keeps exactly min(k, V) survivors, lowest token
    ids winning ties."""
    row = np.array([1, 1, 1, 1, 0, 0, 0, 0], np.float32)
    assert _draw_support(row, SamplingParams(temperature=1.0, top_k=2,
                                             seed=3)) == {0, 1}
    # cutoff inside the tied-zeros group: 4 ones + the lowest-id zero
    assert _draw_support(row, SamplingParams(temperature=1.0, top_k=5,
                                             seed=3)) == {0, 1, 2, 3, 4}


def test_topk_tied_logits_batch():
    """Heterogeneous k over a batch of tied rows in ONE dispatch: each row's
    survivor set is its own exact cutoff."""
    row = np.array([2, 2, 2, 0, 0, 0], np.float32)
    ks = [1, 2, 4, 6]
    B, reps = len(ks), 200
    samp = row_tables(
        [(SamplingParams(temperature=1.0, top_k=k, seed=7), 50 + i)
         for i, k in enumerate(ks) for _ in range(reps)], 0)
    lg = jnp.broadcast_to(jnp.asarray(row), (B * reps, len(row)))
    ages = jnp.tile(jnp.arange(reps, dtype=jnp.int32), B)
    out = np.asarray(sample_rows(lg, samp, ages)).reshape(B, reps)
    support = [set(r.tolist()) for r in out]
    assert support[0] == {0}                   # k=1: lowest-id tied max
    assert support[1] == {0, 1}
    assert support[2] == {0, 1, 2, 3}          # crosses into the 0-ties
    assert support[3] == {0, 1, 2, 3, 4, 5}    # k = V keeps everything


def test_topk_beyond_vocab_clamps_to_full_vocab():
    """top_k > vocab_size must behave exactly like top_k=0 (full vocab):
    same seed/rid/age => bit-identical draws."""
    rng = np.random.default_rng(1)
    row = rng.normal(size=32).astype(np.float32)
    big = _draw_support(row, SamplingParams(temperature=0.8, top_k=10**6,
                                            seed=11), n=64)
    off = _draw_support(row, SamplingParams(temperature=0.8, top_k=0,
                                            seed=11), n=64)
    assert big == off
    # and elementwise, not just as sets
    samp_big = row_tables([(SamplingParams(temperature=0.8, top_k=10**6,
                                           seed=11), 9)] * 64, 0)
    samp_off = row_tables([(SamplingParams(temperature=0.8, top_k=0,
                                           seed=11), 9)] * 64, 0)
    lg = jnp.broadcast_to(jnp.asarray(row), (64, 32))
    ages = jnp.arange(64, dtype=jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(sample_rows(lg, samp_big, ages)),
        np.asarray(sample_rows(lg, samp_off, ages)))


# ---------------------------------------------------------------- penalties
def test_penalties_read_generated_history():
    """Presence/frequency/repetition act on generated tokens only, shifting
    the (greedy) argmax off a repeated token."""
    logits = np.zeros(16, np.float32)
    logits[5], logits[6] = 3.0, 2.5
    greedy = dict(temperature=0.0)
    # no history: plain argmax
    assert sample_oracle(logits, SamplingParams(**greedy), 1, 0, 0) == 5
    # presence: one prior occurrence of 5 knocks it below 6
    p = SamplingParams(presence_penalty=1.0, **greedy)
    assert sample_oracle(logits, p, 1, 0, 1, history=[5]) == 6
    assert sample_oracle(logits, p, 1, 0, 1, history=[4]) == 5  # 5 unseen
    # frequency: scales with the count (one hit is not enough here)
    f = SamplingParams(frequency_penalty=0.3, **greedy)
    assert sample_oracle(logits, f, 1, 0, 2, history=[5]) == 5
    assert sample_oracle(logits, f, 1, 0, 3, history=[5, 5]) == 6
    # repetition (CTRL): positive logit divided by r
    r = SamplingParams(repetition_penalty=4.0, **greedy)
    assert sample_oracle(logits, r, 1, 0, 1, history=[5]) == 6


# -------------------------------------------------- row-placement invariance
def test_draw_invariant_to_row_placement():
    """The same (params, rid, age, logits) must draw the same token at any
    batch row, surrounded by any neighbors — the core ISSUE-9 property."""
    rng = np.random.default_rng(2)
    row = rng.normal(size=64).astype(np.float32)
    p = SamplingParams(temperature=0.7, top_k=12, top_p=0.9, seed=13)
    want = sample_oracle(row, p, rid=42, default_seed=0, age=3)
    neighbors = [
        (SamplingParams(temperature=1.3, seed=1), 7),
        None,                                    # greedy row
        (SamplingParams(temperature=0.0), 8),
    ]
    for pos in range(4):
        resolved = neighbors[:pos] + [(p, 42)] + neighbors[pos:]
        lg = jnp.asarray(rng.normal(size=(4, 64)), jnp.float32)
        lg = lg.at[pos].set(jnp.asarray(row))
        ages = jnp.full(4, 3, jnp.int32)
        out = sample_rows(lg, row_tables(resolved, 0), ages)
        assert int(out[pos]) == want


# ------------------------------------------------------------- engine paths
def _sampled_req(rid, toks, max_new, **kw):
    return Request(rid=rid, arrival_slot=0, tokens=np.asarray(toks, np.int32),
                   max_new_tokens=max_new, sampling=SamplingParams(**kw))


def _greedy_req(rid, toks, max_new=8):
    return Request(rid=rid, arrival_slot=0, tokens=np.asarray(toks, np.int32),
                   max_new_tokens=max_new)


def _dense(cfg, params, **kw):
    base = dict(batch_slots=4, prompt_len=16, cache_len=64)
    base.update(kw)
    return Engine(cfg, params, EngineConfig(**base))


def _run(eng, reqs, mode="sync", max_slots=80):
    eng.submit([copy.deepcopy(r) for r in reqs])
    step = {"sync": eng.step_slot_sync, "fused": eng.step_slot,
            "chunked": eng.step_slot_chunked}[mode]
    t = 0
    while len(eng.finished) < len(reqs) and t < max_slots:
        step(t, n_steps=2)
        t += 1
    if mode in ("sync", "chunked"):
        eng.drain()
    assert len(eng.finished) == len(reqs)
    return {r.rid: tuple(r.generated) for r in eng.finished}


def test_sampled_max_new_exceeds_history_cap_rejected():
    """A sampled request whose max_new_tokens would wrap the gen_buf ring
    (penalty history) is rejected at admission with a one-line error, on
    dense and paged engines alike."""
    cfg, params = _setup()
    toks = np.arange(16, dtype=np.int32) % cfg.vocab_size
    req = _sampled_req(900, toks, max_new=9, temperature=0.8, seed=1)
    eng = _dense(cfg, params, gen_buf_len=8)
    eng.submit([copy.deepcopy(req)])
    with pytest.raises(ValueError, match="history capacity"):
        eng.step_slot(0)
    paged = PagedEngine(cfg, params, PagedEngineConfig(
        prompt_len=16, cache_len=64, page_size=16, num_pages=16,
        max_active=4, gen_buf_len=8))
    paged.submit([copy.deepcopy(req)])
    with pytest.raises(ValueError, match="history capacity"):
        paged.step_slot(0)


def test_requests_sampled_counter():
    cfg, params = _setup()
    rng = np.random.default_rng(3)
    toks = lambda: rng.integers(0, cfg.vocab_size, 16, dtype=np.int32)
    reqs = [_sampled_req(1, toks(), 4, temperature=0.8, seed=1),
            _greedy_req(2, toks(), 4),
            _sampled_req(3, toks(), 4, temperature=0.0)]  # temp-0 = greedy
    eng = _dense(cfg, params)
    _run(eng, reqs, mode="fused")
    # temp-0-with-no-penalties collapses to the pure-greedy path, so only
    # rid 1 counts as sampled
    assert eng.counters()["requests_sampled"] == 1


def test_sampled_stream_survives_disruption():
    """THE ISSUE-9 regression: one seeded sampled request, identical token
    stream under (a) solo at row 0, (b) a different row index among greedy
    neighbors, (c) paged preempt-and-recompute, (d) fleet failure requeue
    to another replica."""
    cfg, params = _setup()
    rng = np.random.default_rng(7)
    prompt = rng.integers(0, cfg.vocab_size, 16, dtype=np.int32)
    skw = dict(temperature=0.9, top_k=8, seed=21)
    target = lambda max_new=12: _sampled_req(777, prompt, max_new, **skw)
    filler = lambda rid: _greedy_req(
        rid, rng.integers(0, cfg.vocab_size, 16, dtype=np.int32), 12)

    # (a) solo reference, row 0
    ref = _run(_dense(cfg, params), [target()], mode="sync")[777]
    assert len(ref) == 12

    # (b) admitted at a different row among greedy neighbors
    eng = _dense(cfg, params)
    eng.submit([filler(1), filler(2), copy.deepcopy(target())])
    eng.step_slot_sync(0, n_steps=1)
    rows = [r.rid if r is not None else None for r in eng.active]
    assert rows.index(777) == 2             # the placement actually differs
    t = 1
    while len(eng.finished) < 3 and t < 80:
        eng.step_slot_sync(t, n_steps=2)
        t += 1
    eng.drain()
    packed = {r.rid: tuple(r.generated) for r in eng.finished}
    assert packed[777] == ref

    # (c) paged preempt-and-recompute (page-starved pool forces a preempt);
    # the longer run's stream must extend the solo stream (prefix property
    # of the request-keyed RNG) and match its own solo reference exactly.
    ref20 = _run(_dense(cfg, params, batch_slots=2), [target(20)],
                 mode="fused")[777]
    assert ref20[:12] == ref
    paged = PagedEngine(cfg, params, PagedEngineConfig(
        prompt_len=16, cache_len=64, page_size=16, num_pages=5,
        max_active=2, max_pages_per_req=3))
    comp = _sampled_req(778, rng.integers(0, cfg.vocab_size, 16,
                                          dtype=np.int32), 20,
                        temperature=1.1, top_p=0.8, seed=4)
    got = _run(paged, [target(20), comp], mode="fused", max_slots=120)
    assert paged.preemptions > 0
    assert got[777] == ref20

    # (d) fleet failure: requeue to the surviving replica mid-stream
    fleet = ReplicaFleet.build(lambda: _dense(cfg, params), 2,
                               router=FleetRouter())
    reqs = [copy.deepcopy(target())] + [filler(i) for i in range(1, 6)]
    fleet.submit([copy.deepcopy(r) for r in reqs])
    for t in range(2):
        fleet.step_slot_sync(t, n_steps=2)
    victim = next(i for i, e in enumerate(fleet.replicas)
                  if any(r is not None and r.rid == 777 for r in e.active)
                  or any(r.rid == 777 for r in e.pending))
    requeued = fleet.fail_replica(victim)
    assert 777 in [r.rid for r in requeued]
    t = 2
    while len(fleet.finished) < len(reqs) and t < 80:
        fleet.step_slot_sync(t, n_steps=2)
        t += 1
    fleet.drain()
    streams = {r.rid: tuple(r.generated) for r in fleet.finished}
    assert streams[777] == ref
