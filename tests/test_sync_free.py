"""The sync-free serving protocol (DESIGN.md §7).

Covers the PR's contract:
  * sync-free generation (device-resident sampling/EOS/ring buffer, async
    counter readback) is bit-identical to the legacy fused path, on the
    dense AND paged engines, for full-length and ragged prompts,
  * zero dispatch-gating blocking syncs per steady-state control slot,
    within the 1-prefill + 1-decode dispatch budget,
  * EOS stops generation identically across step / step_slot /
    step_slot_sync,
  * the module-level engine jits compile once across engine instances
    (no-retrace, mirroring the PR-1 scheduler test),
  * the per-row sampler's exact top-k cutoff agrees with a sort oracle,
  * the scheduler's pipelined control_async is the one-slot-lagged control.
"""
import copy

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.control import DriftPlusPenalty
from repro.models import init_params
from repro.runtime import (
    AdaptiveScheduler,
    Engine,
    EngineConfig,
    PagedEngine,
    PagedEngineConfig,
    PolicyScheduler,
    RequestSource,
    serve,
)
from repro.runtime import engine as eng_mod
from repro.runtime.sampling import SamplingParams, row_tables, sample_rows

KEY = jax.random.PRNGKey(0)
RATES = tuple(float(f) for f in range(1, 9))


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("granite-3-2b", smoke=True)
    params = init_params(KEY, cfg)
    return cfg, params


def _mk_reqs(cfg, n, max_new=6, seed=3, ragged=False):
    src = RequestSource(vocab_size=cfg.vocab_size, prompt_len=16,
                        min_prompt_len=2 if ragged else None,
                        raw_rate=n, max_new_tokens=max_new, seed=seed)
    return src.poll(0, float(n))


def _dense(cfg, params, **kw):
    return Engine(cfg, params, EngineConfig(batch_slots=4, prompt_len=16,
                                            cache_len=64, **kw))


def _paged(cfg, params, **kw):
    return PagedEngine(cfg, params, PagedEngineConfig(
        prompt_len=16, cache_len=64, page_size=16, num_pages=24,
        max_active=8, **kw))


def _drive(eng, reqs, sync, n_steps=2, max_slots=80):
    eng.submit([copy.deepcopy(r) for r in reqs])
    step = eng.step_slot_sync if sync else eng.step_slot
    t = 0
    while len(eng.finished) < len(reqs) and t < max_slots:
        step(t, n_steps=n_steps)
        t += 1
    if sync:
        eng.drain()
    assert len(eng.finished) == len(reqs)
    return {r.rid: r.generated for r in eng.finished}


@pytest.mark.parametrize("ragged", [False, True])
def test_sync_free_matches_legacy_dense(setup, ragged):
    cfg, params = setup
    reqs = _mk_reqs(cfg, 8, ragged=ragged)
    legacy = _drive(_dense(cfg, params), reqs, sync=False)
    sync = _drive(_dense(cfg, params), reqs, sync=True)
    assert legacy == sync


def test_sync_free_matches_legacy_paged_and_dense(setup):
    cfg, params = setup
    reqs = _mk_reqs(cfg, 8, ragged=True)
    dense = _drive(_dense(cfg, params), reqs, sync=False)
    paged_legacy = _drive(_paged(cfg, params), reqs, sync=False)
    paged_sync = _drive(_paged(cfg, params), reqs, sync=True)
    assert paged_legacy == paged_sync == dense


def test_sync_free_paged_preemption_recovers(setup):
    """A pool too small for the offered load must preempt (device rows
    deactivated by the _sync_clear scatter) and still finish every request
    with the dense engine's tokens."""
    cfg, params = setup
    reqs = _mk_reqs(cfg, 6, max_new=10, seed=11)
    tight = PagedEngine(cfg, params, PagedEngineConfig(
        prompt_len=16, cache_len=64, page_size=8, num_pages=10, max_active=8))
    got = _drive(tight, reqs, sync=True, max_slots=200)
    dense = _drive(_dense(cfg, params), reqs, sync=False, max_slots=200)
    assert got == dense


@pytest.mark.parametrize("pattern", [(True,), (False,), (True, False),
                                     (False, False, True)])
def test_sync_free_consume_interleavings(setup, pattern):
    """The early/late consume decision depends on transfer timing
    (``is_ready``) — force every interleaving and require identical tokens.
    Regression for two timing bugs: a stale pre-admission done flag retiring
    a freshly admitted request (admission epochs), and the paged dispatch
    aliasing host pos/block_tables buffers that the never-blocking loop
    mutates before the async decode is guaranteed to have read them."""
    import itertools

    cfg, params = setup
    reqs = _mk_reqs(cfg, 12, ragged=True, seed=7)
    ref = _drive(_dense(cfg, params), reqs, sync=False)

    def forced(eng):
        pat = itertools.cycle(pattern)
        eng._readback_ready = lambda p: next(pat)
        return eng

    assert _drive(forced(_dense(cfg, params)), reqs, sync=True) == ref
    assert _drive(forced(_paged(cfg, params)), reqs, sync=True) == ref


def test_sync_free_zero_blocking_syncs_and_dispatch_budget(setup):
    """The tentpole numbers: 0 dispatch-gating syncs per slot (the legacy
    fused path pays >= 1) within <= 1 prefill + 1 decode dispatch/slot."""
    cfg, params = setup

    def serve_with(sync_free):
        eng = _dense(cfg, params)
        sch = AdaptiveScheduler(rates=RATES, V=20.0, capacity=32)
        src = RequestSource(vocab_size=cfg.vocab_size, prompt_len=16,
                            raw_rate=5, max_new_tokens=4)
        tr = serve(eng, sch, src, horizon=15, steps_per_slot=3,
                   sync_free=sync_free)
        return eng, tr

    eng_s, tr_s = serve_with(True)
    assert eng_s.blocking_syncs == 0
    assert int(tr_s["syncs"].max()) == 0
    assert int(tr_s["dispatches"].max()) <= 2
    assert int(tr_s["served"].sum()) == len(eng_s.finished) > 0
    eng_f, tr_f = serve_with(False)
    assert eng_f.blocking_syncs >= 15  # the fused loop blocks every slot
    assert int(tr_f["syncs"].min()) >= 1


def test_eos_stops_generation_identically(setup):
    """On-device EOS == host EOS: learn a token the model emits, declare it
    EOS, and require step / step_slot / step_slot_sync / paged-sync to agree
    and to stop before max_new_tokens."""
    cfg, params = setup
    reqs = _mk_reqs(cfg, 4, max_new=10, seed=5)
    probe = _drive(_dense(cfg, params), reqs, sync=False)
    eos = probe[reqs[0].rid][2]  # emitted at age 3 of request 0

    def via_step(eng):
        eng.submit([copy.deepcopy(r) for r in reqs])
        for t in range(60):
            if len(eng.finished) == len(reqs):
                break
            eng.step(t)
        return {r.rid: r.generated for r in eng.finished}

    legacy1 = via_step(_dense(cfg, params, eos_id=eos))
    legacy2 = _drive(_dense(cfg, params, eos_id=eos), reqs, sync=False,
                     n_steps=3)
    sync_d = _drive(_dense(cfg, params, eos_id=eos), reqs, sync=True,
                    n_steps=3)
    sync_p = _drive(_paged(cfg, params, eos_id=eos), reqs, sync=True,
                    n_steps=3)
    assert legacy1 == legacy2 == sync_d == sync_p
    g0 = sync_d[reqs[0].rid]
    # stopped at the FIRST occurrence of eos, kept it, and quit early
    assert g0[-1] == eos and eos not in g0[:-1] and len(g0) < 10


@pytest.mark.parametrize("max_new", [1, 2])
def test_sync_admission_instant_finish(setup, max_new):
    """max_new_tokens <= scan edge: the prefill token alone (or one decode
    step) completes the request; neither path may generate past the limit."""
    cfg, params = setup
    reqs = _mk_reqs(cfg, 4, max_new=max_new)
    legacy = _drive(_dense(cfg, params), reqs, sync=False)
    sync = _drive(_dense(cfg, params), reqs, sync=True)
    assert legacy == sync
    assert all(len(g) == max_new for g in sync.values())


def test_gen_buf_capacity_guard(setup):
    cfg, params = setup
    eng = _dense(cfg, params, gen_buf_len=4)
    reqs = _mk_reqs(cfg, 1, max_new=9)
    eng.submit(reqs)
    with pytest.raises(ValueError, match="gen_buf_len"):
        eng.step_slot_sync(0)


def test_no_retrace_across_engine_instances(setup):
    """Regression (mirrors the PR-1 scheduler one-compile test): the engine
    jits are module-level and keyed on (shapes, cfg, sig, n) — building and
    driving a second engine with the same geometry must not re-trace, and
    repeated step_slot calls with the same n reuse one executable."""
    cfg, params = setup
    reqs = _mk_reqs(cfg, 4)
    _drive(_dense(cfg, params), reqs, sync=False)  # ensure everything traced
    _drive(_dense(cfg, params), reqs, sync=True)
    n0 = eng_mod.trace_count()
    _drive(_dense(cfg, params), reqs, sync=False)
    _drive(_dense(cfg, params), reqs, sync=True)
    assert eng_mod.trace_count() == n0


def test_topk_sampler_equivalent_to_sort_oracle():
    """The per-row sampler's top-k cutoff is exact: for distinct logits the
    survivor set equals the sort oracle's top k, and the draw matches a
    hand-masked categorical under the same request-keyed PRNG."""
    logits = jax.random.normal(jax.random.PRNGKey(7), (5, 97), jnp.float32)
    B, V = logits.shape
    rids = list(range(10, 10 + B))
    for k in (1, 5, 96, 97):
        p = SamplingParams(temperature=0.7, top_k=k, seed=5)
        samp = row_tables([(p, r) for r in rids], 0)
        lg = logits / jnp.float32(0.7)
        kth = jnp.sort(lg, axis=-1)[:, -k][:, None]          # the old oracle
        ref = jnp.where(lg < kth, -1e30, lg)
        keys = [jax.random.fold_in(jax.random.fold_in(
            jax.random.PRNGKey(5), r), 0) for r in rids]
        b = jnp.stack([jax.random.categorical(keys[i], ref[i])
                       for i in range(B)]).astype(jnp.int32)
        a = sample_rows(logits, samp, jnp.zeros(B, jnp.int32))
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    g = SamplingParams(temperature=0.0)
    greedy = sample_rows(logits, row_tables([(g, r) for r in rids], 0),
                         jnp.zeros(B, jnp.int32))
    np.testing.assert_array_equal(np.asarray(greedy),
                                  np.asarray(jnp.argmax(logits, -1)))


def test_sampling_mode_sync_free_serves(setup):
    """Non-greedy sync-free decode: valid tokens, everything finishes."""
    cfg, params = setup
    eng = _dense(cfg, params, greedy=False, temperature=0.8, top_k=5)
    reqs = _mk_reqs(cfg, 3, max_new=3)
    got = _drive(eng, reqs, sync=True)
    assert all(0 <= g < cfg.vocab_size for gen in got.values() for g in gen)
    assert all(len(g) == 3 for g in got.values())


def test_control_async_is_lagged_control():
    """control_async(t) must return control's decision for slot t-1 (seeded
    with slot 0's own decision); Static policies stay constant."""
    backlogs = [0, 3, 9, 40, 2, 0, 17]
    sch_ref = PolicyScheduler(policy=DriftPlusPenalty(rates=RATES, V=50.0))
    ref = [sch_ref.control(q) for q in backlogs]
    sch = PolicyScheduler(policy=DriftPlusPenalty(rates=RATES, V=50.0))
    got = [sch.control_async(q) for q in backlogs]
    assert got[0] == ref[0]
    assert got[1:] == ref[:-1]
    from repro.runtime import StaticScheduler

    st = StaticScheduler(rate=4.0)
    assert [st.control_async(q) for q in backlogs] == [4.0] * len(backlogs)


def test_serve_sync_free_totals_match_fused(setup):
    """Same workload end to end: the sync-free serve trace (lagged served
    counts + drain) must account for every finished request, and finished
    token streams must match the fused path's for the requests both
    complete."""
    cfg, params = setup

    def run(sync_free):
        eng = _dense(cfg, params)
        sch = AdaptiveScheduler(rates=RATES[:5], V=20.0, capacity=32)
        src = RequestSource(vocab_size=cfg.vocab_size, prompt_len=16,
                            raw_rate=4, max_new_tokens=4, seed=9)
        tr = serve(eng, sch, src, horizon=12, steps_per_slot=2,
                   sync_free=sync_free)
        return eng, tr

    eng_s, tr_s = run(True)
    eng_f, tr_f = run(False)
    assert int(tr_s["served"].sum()) == len(eng_s.finished)
    # the two runs make different control decisions (lagged vs not), so the
    # same rid names different requests — key by PROMPT: greedy generation
    # is a pure function of it, whichever loop served it
    gen_s = {r.tokens.tobytes(): r.generated for r in eng_s.finished}
    gen_f = {r.tokens.tobytes(): r.generated for r in eng_f.finished}
    common = gen_s.keys() & gen_f.keys()
    assert common and all(gen_s[p] == gen_f[p] for p in common)
