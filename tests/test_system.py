"""End-to-end behaviour tests: sharding rules + tiny-mesh lower/compile.

The full 512-device dry-run is exercised by ``python -m repro.launch.dryrun``
(see EXPERIMENTS.md §Dry-run); here we verify the same code paths lower and
*execute* on a small forced-host mesh so CI catches sharding regressions.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, list_archs
from repro.launch import shardings as SH
from repro.launch.shapes import SHAPES, cache_len_for, input_specs
from repro.models import model as M

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_param_specs_cover_tree_and_divide():
    """Every spec leaf matches its param rank and only shards divisible dims."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    class FakeMesh:  # 16-way checker without 256 devices
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    for arch in list_archs():
        cfg = get_config(arch)
        aparams = M.abstract_params(cfg)
        specs = SH.param_specs(aparams, cfg, FakeMesh())
        flat_p = jax.tree.leaves(aparams)
        flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
        assert len(flat_p) == len(flat_s)
        for p, s in zip(flat_p, flat_s, strict=True):
            assert len(s) <= len(p.shape), (arch, p.shape, s)
            for dim, ax in zip(p.shape, tuple(s) + (None,) * 8, strict=False):
                if ax == "model":
                    assert dim % 16 == 0, (arch, p.shape, s)


def test_decode_state_specs_shard_cache_seq():
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    cfg = get_config("qwen3-8b")
    case = SHAPES["decode_32k"]
    from repro.launch.shapes import decode_inputs

    state, toks = decode_inputs(cfg, case)
    specs = SH.decode_state_specs(state, cfg, FakeMesh(), case.global_batch)
    def norm(ax):
        return (ax,) if isinstance(ax, str) else tuple(ax)

    k_spec = specs.caches[0].k
    assert norm(k_spec[1]) == ("data",)       # batch
    assert norm(k_spec[2]) == ("model",)      # cache sequence stripe
    assert norm(specs.pos[0]) == ("data",)


@pytest.mark.parametrize("arch", ["granite-3-2b", "mamba2-130m", "olmoe-1b-7b"])
def test_tiny_mesh_train_step_executes(arch):
    """Lower AND run a sharded train step on a 1x1 mesh (semantics check)."""
    cfg = get_config(arch, smoke=True)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    from repro.training import AdamW, make_train_step

    opt = AdamW(warmup=1, total_steps=10)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    aparams = jax.eval_shape(lambda: params)
    pspecs = SH.param_specs(aparams, cfg, mesh)
    named = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                         is_leaf=lambda x: isinstance(x, P))
    step = make_train_step(cfg, opt)
    B, S = 4, 32
    batch = {
        "tokens": jnp.zeros((B, S), jnp.int32),
        "targets": jnp.ones((B, S), jnp.int32),
    }
    with mesh:
        jitted = jax.jit(step, in_shardings=(named, None, None))
        p2, o2, metrics = jitted(params, opt.init(params), batch)
    assert np.isfinite(float(metrics["loss"]))


def test_dryrun_entrypoint_smoke():
    """The real dryrun module (512 host devices) runs one small case."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "mamba2-130m",
         "--shape", "decode_32k", "--out", "/tmp/test_dryrun_smoke.jsonl"],
        capture_output=True, text=True, timeout=1200, env=env,
    )
    assert out.returncode == 0, out.stdout + out.stderr
    assert "dry-run complete: 1 ok, 0 failed" in out.stdout


def test_make_production_mesh_is_lazy_import():
    """Importing mesh.py must not initialize jax devices (module hygiene)."""
    code = (
        "import repro.launch.mesh, jax\n"
        "assert not jax._src.xla_bridge._backends, 'devices initialized at import'\n"
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=120, env=env)
    assert out.returncode == 0, out.stdout + out.stderr
