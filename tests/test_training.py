"""Training substrate: optimizer, loss descent, data pipeline, checkpoints."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_params
from repro.training import AdamW, init_train_state, make_train_step, train_loop
from repro.training import checkpoint as ckpt
from repro.training.data import ShardedFileStream, SyntheticStream, write_token_shard

KEY = jax.random.PRNGKey(0)


def test_adamw_converges_quadratic():
    opt = AdamW(lr=0.1, warmup=1, total_steps=200, weight_decay=0.0, grad_clip=1e9)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(150):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state, _ = opt.update(g, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.3


def test_schedule_warmup_and_decay():
    opt = AdamW(lr=1.0, warmup=10, total_steps=100, min_lr_frac=0.1)
    lrs = [float(opt.schedule(jnp.int32(s))) for s in (1, 10, 55, 100)]
    assert lrs[0] == pytest.approx(0.1)
    assert lrs[1] == pytest.approx(1.0)
    assert lrs[1] > lrs[2] > lrs[3]
    assert lrs[3] == pytest.approx(0.1, abs=0.02)


def test_loss_decreases_synthetic():
    cfg = get_config("granite-3-2b", smoke=True)
    stream = SyntheticStream(vocab_size=cfg.vocab_size, seq_len=32, batch_size=4)
    _, _, hist = train_loop(cfg, AdamW(lr=1e-3, warmup=5, total_steps=40), stream, 40)
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.3


def test_grad_clip_bounds_update():
    opt = AdamW(lr=1.0, warmup=1, total_steps=10, grad_clip=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    g = {"w": jnp.asarray([1e6, -1e6, 1e6])}
    p2, _, metrics = opt.update(g, state, params)
    assert float(metrics["grad_norm"]) > 1e5
    assert float(jnp.abs(p2["w"]).max()) < 10.0


def test_file_stream_roundtrip(tmp_path):
    toks = np.arange(1000, dtype=np.uint32) % 97
    path = str(tmp_path / "shard0.bin")
    write_token_shard(path, toks)
    stream = ShardedFileStream(paths=[path], seq_len=16, batch_size=2)
    batch = next(iter(stream))
    assert batch["tokens"].shape == (2, 16)
    np.testing.assert_array_equal(batch["targets"][:, :-1], batch["tokens"][:, 1:])


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_config("qwen3-8b", smoke=True)
    opt = AdamW()
    params, opt_state = init_train_state(KEY, cfg, opt)
    d = ckpt.save(str(tmp_path), {"params": params, "opt": opt_state}, step=7)
    assert os.path.isdir(d)
    template = {"params": params, "opt": opt_state}
    restored, step = ckpt.restore(str(tmp_path), template)
    assert step == 7
    for a, b in zip(jax.tree.leaves(restored["params"]), jax.tree.leaves(params),
                    strict=True):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_rejects_shape_mismatch(tmp_path):
    ckpt.save(str(tmp_path), {"w": jnp.zeros((2, 2))}, step=0)
    with pytest.raises(ValueError):
        ckpt.restore(str(tmp_path), {"w": jnp.zeros((3, 3))})
